//! Registry-driven resolution semantics, end to end: lazy
//! compile-through-the-cache on first request, LRU eviction at capacity,
//! eviction-then-reresolve bit-identity, warmup, and preset models
//! resolving deterministically.

use std::sync::Arc;

use axmul::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig, VariantKey};
use axmul::nn::presets;
use axmul::nn::session::{ModelDesc, SessionCache};
use axmul::nn::QParams;
use axmul::runtime::InferenceBackend;
use axmul::serving::{BackendProvider, ModelRegistry, ServeError};
use axmul::util::rng::Rng;

fn head(name: &str, k: usize, n: usize, seed: u64) -> ModelDesc {
    let mut rng = Rng::new(seed);
    let wq: Vec<u8> = (0..k * n).map(|_| rng.u8()).collect();
    ModelDesc::dense_head(
        name,
        k,
        n,
        wq,
        QParams { scale: 0.02, zero_point: 100 },
        QParams { scale: 1.0 / 255.0, zero_point: 0 },
    )
}

#[test]
fn coordinator_resolves_never_registered_variant_lazily() {
    // the acceptance-criterion scenario: nothing is bound up front; the
    // coordinator's first submit for a variant compiles it through the
    // attached session cache (a miss), every later submit is a hit
    let registry = Arc::new(ModelRegistry::new(Arc::new(SessionCache::new(None))));
    registry.register_model(head("head", 8, 3, 0xBEEF));
    registry.set_default_policy(BatchPolicy { max_batch: 1, ..Default::default() });
    let coord = Coordinator::start(
        Arc::clone(&registry) as Arc<dyn BackendProvider>,
        CoordinatorConfig { workers: 1, ..Default::default() },
    )
    .unwrap();

    assert_eq!(coord.metrics().cache_misses, 0);
    assert!(coord.variants().is_empty());

    let variant = VariantKey::new("head", "exact:reference");
    let input = vec![0.5f32; 8];
    let first = coord.infer(&variant, input.clone()).unwrap();
    let m = coord.metrics();
    assert_eq!((m.cache_misses, m.cache_hits), (1, 0), "first request compiles");
    assert_eq!(coord.variants(), vec![variant.clone()]);
    assert_eq!(coord.output_len(&variant), Some(3));

    for _ in 0..4 {
        let again = coord.infer(&variant, input.clone()).unwrap();
        assert_eq!(again.output, first.output);
    }
    let m = coord.metrics();
    coord.shutdown();
    assert_eq!((m.cache_misses, m.cache_hits), (1, 4), "later requests hit");
    assert_eq!(registry.sessions().len(), 1);
}

#[test]
fn lru_eviction_at_capacity_is_exercised_and_reresolve_is_bit_identical() {
    // capacity 2, three variants of the same model under different LUTs
    let registry = Arc::new(
        ModelRegistry::new(Arc::new(SessionCache::bounded(None, 2))),
    );
    registry.register_model(head("head", 16, 4, 0xE71C));
    let v_exact = VariantKey::new("head", "exact:reference");
    let v_prop = VariantKey::new("head", "proposed:proposed");
    let v_d1 = VariantKey::new("head", "proposed:design1");

    let mut rng = Rng::new(77);
    let input: Vec<f32> = (0..16).map(|_| rng.f64() as f32).collect();
    let out_exact = registry.resolve(&v_exact).unwrap().run_batch_f32(&input, 1).unwrap();
    let out_prop = registry.resolve(&v_prop).unwrap().run_batch_f32(&input, 1).unwrap();
    assert_eq!(registry.sessions().len(), 2);
    assert_eq!(registry.stats().evictions, 0);

    // touch exact so proposed:proposed becomes the least-recently-used,
    // then let a third variant exceed the capacity
    let _ = registry.session(&v_exact).unwrap();
    let _ = registry.resolve(&v_d1).unwrap();
    assert_eq!(registry.sessions().len(), 2);
    assert_eq!(registry.stats().evictions, 1);
    assert!(
        registry.sessions().contains(&v_exact),
        "exact was touched last, proposed:proposed must be the victim"
    );
    assert!(!registry.sessions().contains(&v_prop));

    // evicted variant re-resolves as a fresh compile, bit-identically
    let misses_before = registry.stats().misses;
    let backend = registry.resolve(&v_prop).unwrap();
    assert_eq!(registry.stats().misses, misses_before + 1, "recompile, not a hit");
    assert_eq!(backend.run_batch_f32(&input, 1).unwrap(), out_prop);

    // every variant keeps bit-identical outputs across any sequence of
    // evictions and recompiles
    assert_eq!(
        registry.resolve(&v_exact).unwrap().run_batch_f32(&input, 1).unwrap(),
        out_exact
    );
}

#[test]
fn warmup_precompiles_all_variants() {
    let registry = Arc::new(ModelRegistry::new(Arc::new(SessionCache::new(None))));
    registry.register_model(head("a", 4, 2, 1));
    registry.register_model(head("b", 6, 2, 2));
    let coord = Coordinator::start(
        Arc::clone(&registry) as Arc<dyn BackendProvider>,
        CoordinatorConfig::default(),
    )
    .unwrap();
    let variants = [
        VariantKey::new("a", "exact:reference"),
        VariantKey::new("b", "exact:reference"),
        VariantKey::new("b", "proposed:proposed"),
    ];
    coord.warmup(&variants).unwrap();
    let m = coord.metrics();
    assert_eq!((m.cache_misses, m.cache_hits), (3, 0));
    assert_eq!(coord.variants().len(), 3);
    assert_eq!(coord.output_len(&variants[0]), Some(2));

    // warmed variants serve without further compiles
    coord.infer(&variants[2], vec![0.1; 6]).unwrap();
    let m = coord.metrics();
    coord.shutdown();
    assert_eq!((m.cache_misses, m.cache_hits), (3, 1));

    // warmup on an unknown variant is a typed failure
    let coord = Coordinator::start(
        Arc::clone(&registry) as Arc<dyn BackendProvider>,
        CoordinatorConfig::default(),
    )
    .unwrap();
    assert_eq!(
        coord.warmup(&[VariantKey::new("zzz", "exact:reference")]).err(),
        Some(ServeError::UnknownModel("zzz".into()))
    );
    coord.shutdown();
}

#[test]
fn presets_resolve_and_serve_multi_layer_models() {
    let registry = Arc::new(
        ModelRegistry::new(Arc::new(SessionCache::with_workers(2))).with_max_batch(8),
    );
    registry.register_model(presets::mnist_cnn());
    registry.register_model(presets::lenet5());
    let coord = Coordinator::start(
        Arc::clone(&registry) as Arc<dyn BackendProvider>,
        CoordinatorConfig::default(),
    )
    .unwrap();

    for (model, item_in) in [("mnist_cnn", 28 * 28), ("lenet5", 32 * 32)] {
        let variant = VariantKey::new(model, "proposed:proposed");
        let mut rng = Rng::new(0x9E7 + item_in as u64);
        let input: Vec<f32> = (0..item_in).map(|_| rng.f64() as f32).collect();
        let reply = coord.infer(&variant, input.clone()).unwrap();
        assert_eq!(reply.output.len(), 10, "{model}: 10-class head");
        // serving equals a direct single-item run through the registry
        let direct = registry.resolve(&variant).unwrap().run_batch_f32(&input, 1).unwrap();
        assert_eq!(reply.output, direct, "{model}");
        // and equals a fresh registry in another "process" (determinism)
        let other = ModelRegistry::new(Arc::new(SessionCache::new(None)));
        other.register_model(presets::by_name(model).unwrap());
        let fresh = other.resolve(&variant).unwrap().run_batch_f32(&input, 1).unwrap();
        assert_eq!(reply.output, fresh, "{model}: presets must be deterministic");
    }
    coord.shutdown();
}

#[test]
fn batch_execution_errors_fan_out_as_typed_errors() {
    /// A provider whose backends always fail at execution time.
    struct FailingProvider;
    struct FailingBackend;
    impl InferenceBackend for FailingBackend {
        fn max_batch(&self) -> usize {
            4
        }
        fn item_in(&self) -> usize {
            2
        }
        fn item_out(&self) -> usize {
            1
        }
        fn run_batch_f32(&self, _input: &[f32], _items: usize) -> Result<Vec<f32>, ServeError> {
            Err(ServeError::Execution("injected failure".into()))
        }
    }
    impl BackendProvider for FailingProvider {
        fn resolve(
            &self,
            _key: &VariantKey,
        ) -> Result<Arc<dyn InferenceBackend>, ServeError> {
            Ok(Arc::new(FailingBackend))
        }
    }

    let coord =
        Coordinator::start(Arc::new(FailingProvider), CoordinatorConfig::default()).unwrap();
    let variant = VariantKey::new("any", "any");
    let rx1 = coord.submit(&variant, vec![0.0; 2]).unwrap();
    let rx2 = coord.submit(&variant, vec![1.0; 2]).unwrap();
    for rx in [rx1, rx2] {
        assert_eq!(
            rx.recv().unwrap().err(),
            Some(ServeError::Execution("injected failure".into()))
        );
    }
    let m = coord.metrics();
    coord.shutdown();
    assert_eq!(m.errors, 2);
    assert_eq!(m.requests, 0, "failed requests don't count as served");
}
