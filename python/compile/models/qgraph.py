"""Quantized sequential-graph framework.

A model is a list of layer specs. Building a ``QModel`` runs float
calibration to pick per-layer activation ranges, quantizes weights
(per-tensor, asymmetric uint8), and produces:

* ``apply(x_f32, *weights, lut)`` — the quantized inference function that
  AOT-lowers to the HLO artifact (all multiplies via the product LUT);
* ``weight_arrays()`` — the runtime parameters in order, for the weights
  blob consumed by the Rust runtime;
* ``float_apply(x)`` — the float reference for accuracy baselines.

Scales and zero-points are baked into the HLO as scalar constants (safe:
only large arrays suffer text-form constant elision); weight tensors and
the LUT stay runtime parameters.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.approx_conv import approx_conv2d, quantized_acc_to_int
from ..quant import QParams, qparams_for_tensor, qparams_for_range, quantize_bias

# ---------------------------------------------------------------------------
# Layer specs (float parameters; quantization happens at build time)
# ---------------------------------------------------------------------------


@dataclass
class Conv:
    """Valid 2-D convolution (+ optional left/top zero padding), NHWC."""

    w: np.ndarray  # (KH, KW, Cin, Cout) float
    b: np.ndarray  # (Cout,) float
    relu: bool = True
    pad: int = 0
    name: str = "conv"


@dataclass
class Dense:
    w: np.ndarray  # (K, N) float
    b: np.ndarray  # (N,) float
    relu: bool = False
    name: str = "dense"


@dataclass
class MaxPool2:
    pass


@dataclass
class Flatten:
    pass


@dataclass
class SpaceToDepth2:
    pass


@dataclass
class DepthToSpace2:
    pass


# ---------------------------------------------------------------------------
# Float forward (calibration + baselines)
# ---------------------------------------------------------------------------


def _float_layer(layer, x):
    if isinstance(layer, Conv):
        if layer.pad:
            p = layer.pad
            x = jnp.pad(x, ((0, 0), (p, p), (p, p), (0, 0)))
        y = jax.lax.conv_general_dilated(
            x, jnp.asarray(layer.w), (1, 1), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ) + layer.b
        return jnp.maximum(y, 0.0) if layer.relu else y
    if isinstance(layer, Dense):
        y = x @ jnp.asarray(layer.w) + layer.b
        return jnp.maximum(y, 0.0) if layer.relu else y
    if isinstance(layer, MaxPool2):
        b, h, w, c = x.shape
        return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))
    if isinstance(layer, Flatten):
        return x.reshape(x.shape[0], -1)
    if isinstance(layer, SpaceToDepth2):
        b, h, w, c = x.shape
        return (
            x.reshape(b, h // 2, 2, w // 2, 2, c)
            .transpose(0, 1, 3, 2, 4, 5)
            .reshape(b, h // 2, w // 2, 4 * c)
        )
    if isinstance(layer, DepthToSpace2):
        b, h, w, c = x.shape
        return (
            x.reshape(b, h, w, 2, 2, c // 4)
            .transpose(0, 1, 3, 2, 4, 5)
            .reshape(b, h * 2, w * 2, c // 4)
        )
    raise TypeError(layer)


def float_forward(layers, x):
    for layer in layers:
        x = _float_layer(layer, x)
    return x


# ---------------------------------------------------------------------------
# Quantized model
# ---------------------------------------------------------------------------


@dataclass
class _QLayer:
    spec: object
    w_q: np.ndarray | None = None
    b_q: np.ndarray | None = None
    w_qp: QParams | None = None
    out_qp: QParams | None = None  # activation params after this layer
    requant_mult: float = 1.0
    dequant_scale: float = 1.0


@dataclass
class QModel:
    name: str
    layers: list
    in_qp: QParams
    qlayers: list = field(default_factory=list)
    #: dequantize final accumulator with this scale (last weighted layer)
    final_scale: float = 1.0

    # -- construction -----------------------------------------------------

    @staticmethod
    def build(name: str, layers: list, calib_x: np.ndarray,
              in_range=(0.0, 1.0)) -> "QModel":
        """Quantize a float model using `calib_x` for activation ranges."""
        in_qp = qparams_for_range(*in_range)
        model = QModel(name=name, layers=layers, in_qp=in_qp)
        x = jnp.asarray(calib_x)
        act_qp = in_qp
        for layer in layers:
            x = _float_layer(layer, x)
            ql = _QLayer(spec=layer)
            if isinstance(layer, (Conv, Dense)):
                lo, hi = float(x.min()), float(x.max())
                ql.out_qp = qparams_for_range(lo, hi)
                ql.w_qp = qparams_for_tensor(layer.w)
                ql.w_q = ql.w_qp.quantize(layer.w)
                ql.b_q = quantize_bias(layer.b, act_qp.scale, ql.w_qp.scale)
                ql.requant_mult = act_qp.scale * ql.w_qp.scale / ql.out_qp.scale
                ql.dequant_scale = act_qp.scale * ql.w_qp.scale
                act_qp = ql.out_qp
            else:
                ql.out_qp = act_qp
            model.qlayers.append(ql)
        model.final_scale = model.qlayers[-1].dequant_scale if isinstance(
            layers[-1], (Conv, Dense)) else 1.0
        return model

    # -- runtime parameters -------------------------------------------------

    def weight_arrays(self):
        """(name, array) pairs, in the order `apply` expects them."""
        out = []
        for i, ql in enumerate(self.qlayers):
            if isinstance(ql.spec, (Conv, Dense)):
                out.append((f"{ql.spec.name}{i}_w", ql.w_q))
                out.append((f"{ql.spec.name}{i}_b", ql.b_q))
        return out

    # -- quantized inference (lowers to the artifact) -----------------------

    def apply(self, x, *params):
        """Quantized forward. `params` = [w0, b0, w1, b1, ..., lut]."""
        lut = params[-1]
        weights = list(params[:-1])
        q = jnp.clip(
            jnp.round(x / self.in_qp.scale) + self.in_qp.zero_point, 0, 255
        ).astype(jnp.uint8)
        act_qp = self.in_qp
        wi = 0
        for i, ql in enumerate(self.qlayers):
            spec = ql.spec
            if isinstance(spec, Conv):
                w_q = weights[wi]
                b_q = weights[wi + 1]
                wi += 2
                if spec.pad:
                    p = spec.pad
                    q = jnp.pad(
                        q, ((0, 0), (p, p), (p, p), (0, 0)),
                        constant_values=np.uint8(act_qp.zero_point),
                    )
                acc = approx_conv2d(q, w_q, lut, act_qp.zero_point,
                                    ql.w_qp.zero_point)
                acc = acc + b_q[None, None, None, :]
                q, act_qp = self._requant(acc, ql, i)
            elif isinstance(spec, Dense):
                w_q = weights[wi]
                b_q = weights[wi + 1]
                wi += 2
                acc = quantized_acc_to_int(q, w_q, lut, act_qp.zero_point,
                                           ql.w_qp.zero_point)
                acc = acc + b_q[None, :]
                q, act_qp = self._requant(acc, ql, i)
            elif isinstance(spec, MaxPool2):
                b, h, w, c = q.shape
                q = q.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))
            elif isinstance(spec, Flatten):
                q = q.reshape(q.shape[0], -1)
            elif isinstance(spec, SpaceToDepth2):
                b, h, w, c = q.shape
                q = (q.reshape(b, h // 2, 2, w // 2, 2, c)
                     .transpose(0, 1, 3, 2, 4, 5)
                     .reshape(b, h // 2, w // 2, 4 * c))
            elif isinstance(spec, DepthToSpace2):
                b, h, w, c = q.shape
                q = (q.reshape(b, h, w, 2, 2, c // 4)
                     .transpose(0, 1, 3, 2, 4, 5)
                     .reshape(b, h * 2, w * 2, c // 4))
            else:
                raise TypeError(spec)
        # final output: dequantize (last weighted layer left acc in q via
        # _requant — for the last layer we dequantize instead; see below)
        return self._dequant_out(q, act_qp)

    def _is_last_weighted(self, i: int) -> bool:
        for j in range(i + 1, len(self.qlayers)):
            if isinstance(self.qlayers[j].spec, (Conv, Dense)):
                return False
        return True

    def _requant(self, acc, ql, i):
        spec = ql.spec
        if self._is_last_weighted(i):
            # keep full precision: dequantize at the very end. Represent as
            # float now (accumulator × sx·sw).
            out = acc.astype(jnp.float32) * ql.dequant_scale
            return out, ql.out_qp
        m = jnp.float32(ql.requant_mult)
        q = jnp.round(acc.astype(jnp.float32) * m) + ql.out_qp.zero_point
        if getattr(spec, "relu", False):
            q = jnp.maximum(q, ql.out_qp.zero_point)
        return jnp.clip(q, 0, 255).astype(jnp.uint8), ql.out_qp

    def _dequant_out(self, q, act_qp):
        if q.dtype == jnp.float32:
            return q  # already dequantized by the last weighted layer
        return (q.astype(jnp.float32) - act_qp.zero_point) * act_qp.scale

    # -- float reference ----------------------------------------------------

    def float_apply(self, x):
        return float_forward(self.layers, x)
