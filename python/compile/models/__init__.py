"""L2 models: float training graphs + quantized inference graphs.

`qgraph` is the small quantized-sequential-model framework; `zoo` defines
the paper's three evaluation networks (Keras-style MNIST CNN, LeNet-5,
FFDNet-lite) in both float (training) and quantized (AOT inference) form.
"""

from . import qgraph, zoo
