"""The paper's three evaluation networks (§5).

* ``mnist_cnn`` — the Keras-style CNN of Fig. 5: two conv+pool stages and
  a dense classifier head.
* ``lenet5``   — LeCun et al. 1998, standard shape.
* ``ffdnet_lite`` — FFDNet (Zhang et al. 2018) scaled to this testbed:
  reversible 2× downsampling, a noise-level map channel, a conv stack,
  and 2× upsampling (DESIGN.md §2 substitution).

Each `init_*` returns the float layer list (with randomly initialized
parameters) consumed by `train.py` and, after training, by
`qgraph.QModel.build`.
"""

from __future__ import annotations

import numpy as np

from .qgraph import Conv, Dense, DepthToSpace2, Flatten, MaxPool2, SpaceToDepth2

# fan-in scaled (He) initialization


def _conv_init(rng, kh, kw, cin, cout):
    std = float(np.sqrt(2.0 / (kh * kw * cin)))
    return rng.normal(0.0, std, (kh, kw, cin, cout)).astype(np.float32)


def _dense_init(rng, k, n):
    std = float(np.sqrt(2.0 / k))
    return rng.normal(0.0, std, (k, n)).astype(np.float32)


def _zeros(n):
    return np.zeros((n,), dtype=np.float32)


def init_mnist_cnn(seed: int = 11):
    """Fig. 5 CNN: 28×28×1 → conv3×3×8 (SAME) → pool → conv3×3×16 → pool
    → dense10. Spatial flow: 28 → 14 → 12 → 6."""
    rng = np.random.default_rng(seed)
    return [
        Conv(_conv_init(rng, 3, 3, 1, 8), _zeros(8), relu=True, pad=1, name="conv"),
        MaxPool2(),
        Conv(_conv_init(rng, 3, 3, 8, 16), _zeros(16), relu=True, name="conv"),
        MaxPool2(),
        Flatten(),
        Dense(_dense_init(rng, 6 * 6 * 16, 10), _zeros(10), relu=False, name="fc"),
    ]


def init_lenet5(seed: int = 13):
    """LeNet-5: conv5×5×6 → pool → conv5×5×16 → pool → fc120 → fc84 → fc10."""
    rng = np.random.default_rng(seed)
    return [
        Conv(_conv_init(rng, 5, 5, 1, 6), _zeros(6), relu=True, pad=2, name="conv"),
        MaxPool2(),
        Conv(_conv_init(rng, 5, 5, 6, 16), _zeros(16), relu=True, name="conv"),
        MaxPool2(),
        Flatten(),
        Dense(_dense_init(rng, 5 * 5 * 16, 120), _zeros(120), relu=True, name="fc"),
        Dense(_dense_init(rng, 120, 84), _zeros(84), relu=True, name="fc"),
        Dense(_dense_init(rng, 84, 10), _zeros(10), relu=False, name="fc"),
    ]


FFDNET_CH = 24


def init_ffdnet_lite(seed: int = 17):
    """FFDNet-lite on (B, 32, 32, 2): ch0 = noisy image, ch1 = σ map.

    space_to_depth(2) turns the 2-channel input into 8 channels at 16×16
    (4 image sub-bands + 4 copies of the noise map), followed by four
    SAME 3×3 convs and depth_to_space back to 32×32×1... the final conv
    emits 4 channels = the 2×2 sub-band estimate of the clean image.
    """
    rng = np.random.default_rng(seed)
    ch = FFDNET_CH
    return [
        SpaceToDepth2(),
        Conv(_conv_init(rng, 3, 3, 8, ch), _zeros(ch), relu=True, pad=1, name="conv"),
        Conv(_conv_init(rng, 3, 3, ch, ch), _zeros(ch), relu=True, pad=1, name="conv"),
        Conv(_conv_init(rng, 3, 3, ch, ch), _zeros(ch), relu=True, pad=1, name="conv"),
        Conv(_conv_init(rng, 3, 3, ch, 4), _zeros(4), relu=False, pad=1, name="conv"),
        DepthToSpace2(),
    ]


def ffdnet_input(noisy: np.ndarray, sigma255: float) -> np.ndarray:
    """Pack (B, 32, 32, 1) noisy images + scalar σ into the model input."""
    b, h, w, _ = noisy.shape
    sigma_map = np.full((b, h, w, 1), sigma255 / 255.0, dtype=np.float32)
    return np.concatenate([noisy, sigma_map], axis=-1)
