"""8x8 unsigned approximate multiplier: the three PPR architectures.

Vectorized numpy simulation over the complete 65,536-pair input space.
Every wire is a ``uint8`` array of shape ``(65536,)`` (one lane per input
pair); compressors evaluate via 16-entry table lookups on packed 4-bit
combination indices, so a full exhaustive multiplier sim is a handful of
vectorized ops per column.

Architectures (paper Fig. 2):

* ``design1``  — exact 4:2 compressors in the MSB columns (k >= n),
  approximate compressors in the LSB columns (k < n).
* ``design2``  — columns 0..n-5 truncated; probabilistic error-compensation
  constant added; approximate compressors elsewhere.
* ``proposed`` — approximate compressors in *every* column.

Reduction tree (all architectures): staged column chunking — groups of 4
bits -> 4:2 compressor (carry into next stage, column k+1); leftover of 3
-> the column's compressor with a constant-0 fourth input (exact columns
use a full adder); leftover of 2 -> half adder; repeat until every column
holds <= 2 bits; exact carry-propagate add finishes. This joint-calibrates
best against the paper's two independently-known fingerprints (proposed
and [16]-D2 Table 2 rows); see DESIGN.md §4 for the deviation note vs the
paper's (unspecified) tree.
"""

from __future__ import annotations

import numpy as np

from .compressors import EXACT, CompressorTable

__all__ = [
    "ARCHITECTURES",
    "N_BITS",
    "multiply_exhaustive",
    "multiply_pairs",
    "error_metrics",
    "product_lut",
    "truncation_compensation",
]

N_BITS = 8
ARCHITECTURES = ("design1", "design2", "proposed")


def _pp_columns(a: np.ndarray, b: np.ndarray):
    """Partial-product bit columns for ``a*b`` (uint8 arrays of 0/1)."""
    cols: list[list[np.ndarray]] = [[] for _ in range(2 * N_BITS)]
    for i in range(N_BITS):
        ai = ((a >> i) & 1).astype(np.uint8)
        for j in range(N_BITS):
            bj = ((b >> j) & 1).astype(np.uint8)
            cols[i + j].append(ai & bj)
    return cols


def _full_adder(x, y, z):
    s = x ^ y ^ z
    c = (x & y) | (x & z) | (y & z)
    return c, s


def truncation_compensation(n: int = N_BITS, cut: int | None = None) -> int:
    """Design-2 compensation constant: round(E[sum of truncated PP bits]).

    Each partial-product bit is 1 with probability 1/4; column k < cut has
    min(k+1, 2n-1-k) bits of weight 2^k.
    """
    if cut is None:
        cut = n - 4
    expected = sum(min(k + 1, 2 * n - 1 - k) * (2 ** k) for k in range(cut)) / 4.0
    return int(round(expected))


def multiply_pairs(a, b, table: CompressorTable, arch: str = "proposed"):
    """Approximate products for uint8 arrays ``a``, ``b`` (vectorized)."""
    a = np.asarray(a, dtype=np.uint16)
    b = np.asarray(b, dtype=np.uint16)
    if arch not in ARCHITECTURES:
        raise ValueError(f"unknown architecture {arch!r}")

    cols = _pp_columns(a, b)

    compensation = 0
    if arch == "design2":
        cut = N_BITS - 4
        compensation = truncation_compensation(N_BITS, cut)
        for k in range(cut):
            cols[k] = []

    # Fig. 2(a) and (b) both "use a mix of exact and approximate
    # compressors": exact compressors guard the MSB columns in the two
    # baseline architectures; only the proposed one approximates throughout.
    if arch in ("design1", "design2"):

        def is_approx(k):
            return k < N_BITS

    else:

        def is_approx(k):
            return True

    # Tables containing the value 4 (the exact compressor) need a cout; two
    # chained full adders are exactly equivalent for 4 inputs, so any
    # "approximate" column whose table is exact uses that path instead.
    approx_carry, approx_sum = table.carry_sum_tables()
    table_is_exact = max(table.values) > 3
    zero = None

    def stage(cols):
        nonlocal zero
        out: list[list[np.ndarray]] = [[] for _ in range(len(cols) + 2)]
        for k, col in enumerate(cols):
            bits = col
            if zero is None and bits:
                zero = np.zeros_like(bits[0])
            i = 0

            def approx4(x1, x2, x3, x4):
                idx = (x1 + (x2 << 1) + (x3 << 2) + (x4 << 3)).astype(np.uint8)
                return approx_carry[idx], approx_sum[idx]

            while len(bits) - i >= 4:
                x1, x2, x3, x4 = bits[i : i + 4]
                if is_approx(k) and not table_is_exact:
                    c, s = approx4(x1, x2, x3, x4)
                    out[k].append(s)
                    out[k + 1].append(c)
                else:
                    # exact 4:2 as two chained FAs (cin=0): cout to k+1 too
                    c1, s1 = _full_adder(x1, x2, x3)
                    c2, s2 = _full_adder(s1, x4, np.zeros_like(x4))
                    out[k].append(s2)
                    out[k + 1].append(c1)
                    out[k + 1].append(c2)
                i += 4
            rem = len(bits) - i
            if rem == 3:
                if is_approx(k) and not table_is_exact:
                    # "only approximate compressors throughout": pad with 0
                    c, s = approx4(bits[i], bits[i + 1], bits[i + 2], zero)
                else:
                    c, s = _full_adder(*bits[i : i + 3])
                out[k].append(s)
                out[k + 1].append(c)
                i += 3
            elif rem == 2:
                c = bits[i] & bits[i + 1]
                s = bits[i] ^ bits[i + 1]
                out[k].append(s)
                out[k + 1].append(c)
                i += 2
            out[k].extend(bits[i:])
        while out and not out[-1]:
            out.pop()
        return out

    guard = 0
    while max((len(c) for c in cols), default=0) > 2 and guard < 16:
        cols = stage(cols)
        guard += 1

    total = np.zeros(a.shape, dtype=np.int64)
    for k, col in enumerate(cols):
        for bit in col:
            total += bit.astype(np.int64) << k
    return total + compensation


def multiply_exhaustive(table: CompressorTable, arch: str = "proposed"):
    """All 65,536 products ``a*b`` for a, b in 0..255 (index = a*256+b)."""
    pairs = np.arange(65536, dtype=np.uint32)
    a = (pairs >> 8).astype(np.uint16)
    b = (pairs & 255).astype(np.uint16)
    return multiply_pairs(a, b, table, arch)


def error_metrics(approx: np.ndarray):
    """(ER%, NMED%, MRED%) against the exact product, paper Eqs. (4)-(7)."""
    pairs = np.arange(65536, dtype=np.int64)
    exact = (pairs >> 8) * (pairs & 255)
    ed = np.abs(approx.astype(np.int64) - exact)
    er = float(np.mean(ed > 0) * 100.0)
    nmed = float(ed.mean() / (255 * 255) * 100.0)
    nz = exact > 0
    mred = float((ed[nz] / exact[nz]).mean() * 100.0)
    return er, nmed, mred


def product_lut(table: CompressorTable, arch: str = "proposed") -> np.ndarray:
    """256x256 -> u32 product table (flat, index = a*256 + b).

    This is the artifact consumed by the L1 Pallas kernel and the L3
    runtime: the entire multiplier design, gate-accurately, as data.
    """
    return multiply_exhaustive(table, arch).astype(np.uint32)
