"""4:2 compressor behavioral models (truth tables).

Every compressor is a mapping from the 16 input combinations
``(x4, x3, x2, x1)`` to an approximate value ``2*carry + sum`` in ``0..3``
(the exact compressor additionally produces ``cout``, encoding values up
to 5; within the paper's multipliers it is only ever fed 4 partial-product
bits, so values 0..4 occur and the exact model uses carry/cout/sum).

Input combination index convention: ``idx = x1 + 2*x2 + 4*x3 + 8*x4``.
Under the partial-product distribution each input bit is 1 with
probability 1/4, so a combination with ``k`` ones has probability
``3^(4-k) / 256``.

Provenance of the comparison designs
------------------------------------
The paper (survey §2, Tables 2/3) gives, for each referenced design, the
error probability, the number of erroneous combinations, structural hints,
and the multiplier-level ER/NMED/MRED in the proposed PPR architecture.
Original netlists are not reproduced in the paper, so:

* high-accuracy designs ([16]-D1, [17]-D3, [18], [19]-D1/D5, proposed) all
  share the canonical single-error table ``value = min(x1+x2+x3+x4, 3)``
  (the paper states all of them err only on ``1111``); they differ in gate
  structure only (modeled on the Rust side for Table 3);
* [16]-D2 follows in closed form from "only OR and AND gates":
  ``carry = x1x2 + x3x4``, ``sum = x1 + x2 + x3 + x4`` — this independently
  reproduces the stated 7 error combinations and P = 55/256;
* [12], [15], [17]-D2 and [13] are reconstructed by constrained search over
  error signatures consistent with the stated probabilities
  (19/256, 16/256, 4/256, 70/256), selecting the signature whose
  multiplier-level (ER, NMED, MRED) is closest to the paper's Table 2 row
  (see ``calibrate.py``; the frozen results are inlined below with their
  achieved metrics).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = [
    "CompressorTable",
    "EXACT",
    "HIGH_ACCURACY",
    "DESIGNS",
    "design_names",
    "COMBO_PROB_NUM",
]

#: numerator (over 256) of each combination's probability: 3^(4 - popcount).
COMBO_PROB_NUM = tuple(3 ** (4 - bin(i).count("1")) for i in range(16))


@dataclass(frozen=True)
class CompressorTable:
    """Behavioral 4:2 compressor: approximate value per input combination."""

    name: str
    #: ``values[idx]`` = approximate ``2*carry + sum`` for combination idx
    #: (exact table stores the true count, 0..4, using cout for 4).
    values: tuple
    #: human-readable provenance / citation tag
    source: str = ""

    def __post_init__(self):
        assert len(self.values) == 16, self.name
        assert all(0 <= v <= 4 for v in self.values), self.name

    # -- error signature ----------------------------------------------------
    def error_combos(self):
        """Indices where the approximate value differs from the true count."""
        return [i for i in range(16) if self.values[i] != bin(i).count("1")]

    def error_probability_num(self) -> int:
        """Numerator over 256 of the error probability."""
        return sum(COMBO_PROB_NUM[i] for i in self.error_combos())

    # -- vectorized evaluation ----------------------------------------------
    def carry_sum_tables(self):
        """(carry, sum) lookup arrays over the 16 combinations.

        Values of 4 (exact table only) are encoded as carry=0, sum=0 with
        cout=1; callers that support cout must use :meth:`cout_table`.
        """
        vals = np.asarray(self.values, dtype=np.int64)
        return ((vals >> 1) & 1).astype(np.uint8), (vals & 1).astype(np.uint8)

    def cout_table(self):
        vals = np.asarray(self.values, dtype=np.int64)
        return (vals >= 4).astype(np.uint8)

    def value_table(self):
        return np.asarray(self.values, dtype=np.int64)


def _table_from_errors(errors: dict) -> tuple:
    """Build a value table = exact count except for the given overrides."""
    return tuple(errors.get(i, bin(i).count("1")) for i in range(16))


def _idx(x4: int, x3: int, x2: int, x1: int) -> int:
    return x1 + 2 * x2 + 4 * x3 + 8 * x4


# ---------------------------------------------------------------------------
# Exact and the canonical single-error (high-accuracy) table
# ---------------------------------------------------------------------------

EXACT = CompressorTable(
    "exact",
    tuple(bin(i).count("1") for i in range(16)),
    source="exact 4:2 compressor (two cascaded full adders), Fig. 1",
)

#: value = min(sum, 3): the single error is 1111 -> 3 (true 4), P = 1/256.
HIGH_ACCURACY = CompressorTable(
    "high_accuracy",
    tuple(min(bin(i).count("1"), 3) for i in range(16)),
    source="canonical single-error 4:2 table shared by [16]-D1, [17]-D3, "
    "[18], [19]-D1/D5 and the proposed design (paper §2.2, Table 1)",
)

# The proposed compressor: verified against Table 1 / Eqs. (1)-(3)
# (with the Eq. (2) typo corrected: third product term A·C̄·D, not Ā·C̄·D).
# Behaviorally identical to HIGH_ACCURACY; kept as its own named entry.
PROPOSED = CompressorTable("proposed", HIGH_ACCURACY.values,
                           source="this paper, Table 1 / Eqs. (1)-(3)")


def proposed_from_equations(x1: int, x2: int, x3: int, x4: int) -> int:
    """Gate-level evaluation of the paper's Eqs. (1)-(3) (typo corrected).

    Used by tests to confirm the equations reproduce Table 1 and the
    behavioral table above.
    """
    A = 1 - (x1 | x2)
    B = 1 - (x1 & x2)
    C = 1 - (x3 | x4)
    D = 1 - (x3 & x4)
    carry = (1 - (B & D)) | (1 - (A | C))
    nA, nB, nC, nD = 1 - A, 1 - B, 1 - C, 1 - D
    s = (nA & B & C) | (nA & B & nD) | (A & nC & D) | (nB & nC & D) | (nB & nD)
    return 2 * carry + s


# ---------------------------------------------------------------------------
# Low-accuracy comparison designs
# ---------------------------------------------------------------------------

def _kumari16_d2_values() -> tuple:
    """[16]-D2: OR/AND only — carry = x1x2 + x3x4, sum = x1+x2+x3+x4 (OR)."""
    vals = []
    for i in range(16):
        x1, x2, x3, x4 = i & 1, (i >> 1) & 1, (i >> 2) & 1, (i >> 3) & 1
        carry = (x1 & x2) | (x3 & x4)
        s = x1 | x2 | x3 | x4
        vals.append(2 * carry + s)
    return tuple(vals)


KUMARI16_D2 = CompressorTable(
    "kumari16_d2",
    _kumari16_d2_values(),
    source="[16] Kumari & Palathinkal, TCAS-I 2025, Design-2 (OR/AND only); "
    "closed form, 7 error combos, P = 55/256 (matches paper Table 3)",
)

# Reconstructed signatures (see module docstring + calibrate.py). Each is
# written as {combo_idx: approximate_value} overrides of the exact count.
# Combo index = x1 + 2*x2 + 4*x3 + 8*x4.
#
#   [12] Krishna et al., ESL 2024 — stated P = 19/256 (= 9 + 9 + 1).
#        NOTE: the paper's prose says "two combination errors", which cannot
#        sum to 19/256; Table 3's probability requires three combos. We
#        follow Table 3.
#   [15] Anil Kumar et al. (CAAM), ESL 2023 — P = 16/256 (= 9 + 3 + 3 + 1).
#   [17] Strollo et al., TCAS-I 2020 Design-2 — P = 4/256 (= 3 + 1).
#   [13] Zhang et al., TCAS-II 2023 — P = 70/256 (= 27+27+9+3+3+1).

# --- frozen calibration results (generated by calibrate.py) ---------------
# Each entry: combo index (= x1 + 2*x2 + 4*x3 + 8*x4) -> approximate value.
# Achieved multiplier-level metrics in the proposed PPR architecture vs the
# paper's Table 2 targets (ER%, NMED%, MRED%):
#   krishna12    (68.954, 0.696, 3.364)  target (68.498, 0.596, 3.496)
#   caam15       (66.090, 0.660, 3.224)  target (65.425, 0.673, 3.531)
#   strollo17_d2 (21.788, 0.256, 0.569)  target (21.296, 0.162, 0.578)
#   zhang13      (97.357, 2.264, 20.718) target (95.681, 1.565, 20.276)
KRISHNA12_ERRORS = {9: 1, 12: 3, 15: 3}
CAAM15_ERRORS = {12: 3, 11: 2, 14: 2, 15: 3}
STROLLO17_D2_ERRORS = {7: 2, 15: 3}
ZHANG13_ERRORS = {2: 0, 8: 2, 10: 3, 11: 2, 13: 2, 15: 3}

KRISHNA12 = CompressorTable(
    "krishna12", _table_from_errors(KRISHNA12_ERRORS),
    source="[12] Krishna et al., ESL 2024; reconstructed signature, P=19/256")
CAAM15 = CompressorTable(
    "caam15", _table_from_errors(CAAM15_ERRORS),
    source="[15] Anil Kumar et al., ESL 2023 (CAAM); reconstructed, P=16/256")
STROLLO17_D2 = CompressorTable(
    "strollo17_d2", _table_from_errors(STROLLO17_D2_ERRORS),
    source="[17] Strollo et al., TCAS-I 2020 Design-2; reconstructed, P=4/256")
ZHANG13 = CompressorTable(
    "zhang13", _table_from_errors(ZHANG13_ERRORS),
    source="[13] Zhang et al., TCAS-II 2023; reconstructed, P=70/256")

# High-accuracy named aliases (behaviorally identical, distinct netlists).
KUMARI16_D1 = CompressorTable("kumari16_d1", HIGH_ACCURACY.values,
                              source="[16] Design-1, single error at 1111")
STROLLO17_D3 = CompressorTable("strollo17_d3", HIGH_ACCURACY.values,
                               source="[17] Design-3, single error at 1111")
YANG18 = CompressorTable("yang18", HIGH_ACCURACY.values,
                         source="[18] Yang et al., DFTS 2015, Design-1")
KONG19_D1 = CompressorTable("kong19_d1", HIGH_ACCURACY.values,
                            source="[19] Kong & Li, TVLSI 2021, Design-1")
KONG19_D5 = CompressorTable("kong19_d5", HIGH_ACCURACY.values,
                            source="[19] Kong & Li, TVLSI 2021, Design-5")

#: Registry in the paper's Table 2 row order.
DESIGNS = {
    d.name: d
    for d in (
        EXACT,
        KRISHNA12,       # [12]
        CAAM15,          # [15]
        KUMARI16_D1,     # [16] high-accuracy
        KUMARI16_D2,     # [16] low-accuracy
        STROLLO17_D2,    # [17] Design-2
        STROLLO17_D3,    # [17] Design-3
        KONG19_D1,       # [19] Design-1
        KONG19_D5,       # [19] Design-5
        ZHANG13,         # [13]
        YANG18,          # [18]
        PROPOSED,
    )
}


def design_names(include_exact: bool = True):
    names = list(DESIGNS)
    if not include_exact:
        names.remove("exact")
    return names
