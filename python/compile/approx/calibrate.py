"""Calibration search for reconstructed baseline compressor signatures.

For each comparison design whose netlist is not given in the paper, search
over error signatures consistent with its stated error probability and pick
the one whose multiplier-level (ER, NMED, MRED) in the proposed PPR
architecture is closest to the paper's Table 2 row. Run with:

    python -m compile.approx.calibrate

and paste the frozen dicts into ``compressors.py``. This script is kept in
the repo as provenance for the frozen signatures.
"""

from __future__ import annotations

import itertools

import numpy as np

from .compressors import CompressorTable, _table_from_errors
from .multiplier import error_metrics, multiply_exhaustive

# Paper Table 2 targets in the proposed architecture: (ER%, NMED%, MRED%).
TARGETS = {
    "krishna12": (68.498, 0.596, 3.496),
    "caam15": (65.425, 0.673, 3.531),
    "strollo17_d2": (21.296, 0.162, 0.578),
    "zhang13": (95.681, 1.565, 20.276),
}

SINGLES = [1, 2, 4, 8]
DOUBLES = [3, 5, 6, 9, 10, 12]
TRIPLES = [7, 11, 13, 14]
QUAD = 15


def score(errors: dict, target) -> float:
    tbl = CompressorTable("cand", _table_from_errors(errors))
    m = error_metrics(multiply_exhaustive(tbl, "proposed"))
    return sum(abs(a - t) / max(t, 1e-9) for a, t in zip(m, target)), m


def search(candidates, target, label):
    best = None
    for errors in candidates:
        s, m = score(errors, target)
        if best is None or s < best[0]:
            best = (s, errors, m)
    s, errors, m = best
    print(f"{label}: score={s:.4f} metrics={tuple(round(x,3) for x in m)} "
          f"target={target}\n  errors={errors}")
    return errors


def candidates_krishna12():
    """P = 19/256 = 9 + 9 + 1: two 2-one combos + 1111."""
    for d1, d2 in itertools.combinations(DOUBLES, 2):
        for v1, v2 in itertools.product((0, 1, 3), repeat=2):
            for vq in (0, 1, 2, 3):
                yield {d1: v1, d2: v2, QUAD: vq}


def candidates_caam15():
    """P = 16/256 = 9 + 3 + 3 + 1."""
    for d in DOUBLES:
        for t1, t2 in itertools.combinations(TRIPLES, 2):
            for vd in (0, 1, 3):
                for vt1, vt2 in itertools.product((0, 1, 2), repeat=2):
                    for vq in (0, 1, 2, 3):
                        yield {d: vd, t1: vt1, t2: vt2, QUAD: vq}


def candidates_strollo17_d2():
    """P = 4/256 = 3 + 1: one 3-one combo + 1111."""
    for t in TRIPLES:
        for vt in (0, 1, 2):
            for vq in (0, 1, 2, 3):
                yield {t: vt, QUAD: vq}


def candidates_zhang13():
    """P = 70/256 = 27 + 27 + 9 + 3 + 3 + 1."""
    for s1, s2 in itertools.combinations(SINGLES, 2):
        for d in DOUBLES:
            for t1, t2 in itertools.combinations(TRIPLES, 2):
                for vs in ((0, 0), (2, 2), (0, 2)):
                    for vd in (0, 1, 3):
                        for vt in ((2, 2), (1, 1), (2, 1)):
                            for vq in (2, 3):
                                yield {s1: vs[0], s2: vs[1], d: vd,
                                       t1: vt[0], t2: vt[1], QUAD: vq}


def main():
    frozen = {}
    frozen["strollo17_d2"] = search(
        candidates_strollo17_d2(), TARGETS["strollo17_d2"], "strollo17_d2")
    frozen["krishna12"] = search(
        candidates_krishna12(), TARGETS["krishna12"], "krishna12")
    frozen["caam15"] = search(
        candidates_caam15(), TARGETS["caam15"], "caam15")
    frozen["zhang13"] = search(
        candidates_zhang13(), TARGETS["zhang13"], "zhang13")
    print("\nfrozen:")
    for k, v in frozen.items():
        print(f"{k.upper()}_ERRORS = {v!r}")


if __name__ == "__main__":
    main()
