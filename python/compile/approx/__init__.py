"""Behavioral models of the paper's approximate arithmetic.

This package is the *build-time* (Python) twin of the Rust `compressor` /
`multiplier` / `lut` modules: truth-table compressor models, the three 8x8
partial-product-reduction architectures, exhaustive error metrics, and
product-LUT generation. The Rust side re-derives every LUT independently and
the cross-language tests assert bit-identical results.
"""

from .compressors import (
    CompressorTable,
    DESIGNS,
    EXACT,
    HIGH_ACCURACY,
    design_names,
)
from .multiplier import (
    ARCHITECTURES,
    multiply_exhaustive,
    error_metrics,
    product_lut,
)
