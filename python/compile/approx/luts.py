"""Product-LUT binary I/O — the Python twin of ``rust/src/lut/mod.rs``.

Format (`.axlut`, little-endian):
    magic   8 bytes   b"AXLUT01\\0"
    nlen    4 bytes   u32 name length
    name    nlen      utf-8 "<design>:<architecture>"
    data    262144    65,536 x u32 products
    fnv     8 bytes   FNV-1a64 over the data bytes

The Rust side re-generates every LUT independently from its own behavioral
model; integration tests assert byte-identical artifacts.
"""

from __future__ import annotations

import struct
from pathlib import Path

import numpy as np

MAGIC = b"AXLUT01\x00"
ENTRIES = 65536


def fnv1a64(data: bytes) -> int:
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x00000100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def write_lut(path: Path, name: str, data: np.ndarray) -> None:
    assert data.shape == (ENTRIES,) and data.dtype == np.uint32, (data.shape, data.dtype)
    raw = data.astype("<u4").tobytes()
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(name.encode())))
        f.write(name.encode())
        f.write(raw)
        f.write(struct.pack("<Q", fnv1a64(raw)))


def read_lut(path: Path):
    with open(path, "rb") as f:
        magic = f.read(8)
        if magic != MAGIC:
            raise ValueError(f"{path}: bad magic {magic!r}")
        (nlen,) = struct.unpack("<I", f.read(4))
        name = f.read(nlen).decode()
        raw = f.read(ENTRIES * 4)
        (check,) = struct.unpack("<Q", f.read(8))
        if check != fnv1a64(raw):
            raise ValueError(f"{path}: checksum mismatch")
        return name, np.frombuffer(raw, dtype="<u4").copy()
