"""Synthetic datasets (seeded, procedural).

MNIST and natural-image corpora are not available in this offline
environment (DESIGN.md §2 substitution table), so:

* ``digits_dataset`` — a 10-class 28×28 grayscale digit task: 7×5 bitmap
  glyphs, randomly scaled/shifted/thickened, with background and sensor
  noise. Same sizes as the paper's MNIST subset (5,000 train / 500 test).
* ``texture_dataset`` — 32×32 grayscale images mixing sinusoidal gratings,
  checkerboards, blobs and glyph overlays; used to train/evaluate the
  FFDNet-lite denoiser with AWGN at σ = 25/50 (on the 0..255 scale).
"""

from __future__ import annotations

import numpy as np

# 7×5 bitmap font for digits 0-9.
_FONT = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00110", "01000", "10000", "11111"],
    3: ["11110", "00001", "00001", "01110", "00001", "00001", "11110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}


def _glyph(d: int) -> np.ndarray:
    return np.array([[float(c) for c in row] for row in _FONT[d]], dtype=np.float32)


def _render_digit(d: int, rng: np.random.Generator) -> np.ndarray:
    """One 28×28 sample: scaled, shifted, thickened, noisy glyph."""
    g = _glyph(d)
    # upscale by 2-3× with nearest-neighbour
    s = rng.integers(2, 4)
    g = np.kron(g, np.ones((s, s), dtype=np.float32))
    # random thickening (dilation with a cross kernel)
    if rng.random() < 0.5:
        p = np.pad(g, 1)
        g = np.maximum.reduce(
            [p[1:-1, 1:-1], p[:-2, 1:-1], p[2:, 1:-1], p[1:-1, :-2], p[1:-1, 2:]]
        )
    img = np.zeros((28, 28), dtype=np.float32)
    gh, gw = g.shape
    max_y, max_x = 28 - gh, 28 - gw
    y = rng.integers(max(0, max_y // 2 - 3), min(max_y, max_y // 2 + 3) + 1)
    x = rng.integers(max(0, max_x // 2 - 3), min(max_x, max_x // 2 + 3) + 1)
    img[y : y + gh, x : x + gw] = g
    # intensity variation + blur-ish smoothing + noise
    img *= rng.uniform(0.7, 1.0)
    img = 0.25 * np.roll(img, 1, 0) + 0.25 * np.roll(img, 1, 1) + 0.5 * img
    img += rng.normal(0.0, 0.05, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def digits_dataset(n_train: int = 5000, n_test: int = 500, seed: int = 1234):
    """Returns (x_train, y_train, x_test, y_test); images (N, 28, 28, 1)."""
    rng = np.random.default_rng(seed)

    def make(n, rng):
        xs = np.empty((n, 28, 28, 1), dtype=np.float32)
        ys = np.empty((n,), dtype=np.int32)
        for i in range(n):
            d = int(rng.integers(0, 10))
            xs[i, :, :, 0] = _render_digit(d, rng)
            ys[i] = d
        return xs, ys

    x_train, y_train = make(n_train, rng)
    x_test, y_test = make(n_test, np.random.default_rng(seed + 1))
    return x_train, y_train, x_test, y_test


def _texture(rng: np.random.Generator, size: int = 32) -> np.ndarray:
    yy, xx = np.meshgrid(np.arange(size), np.arange(size), indexing="ij")
    kind = rng.integers(0, 4)
    if kind == 0:  # sinusoidal grating
        fx, fy = rng.uniform(0.05, 0.5, 2)
        phase = rng.uniform(0, 2 * np.pi)
        img = 0.5 + 0.5 * np.sin(2 * np.pi * (fx * xx + fy * yy) + phase)
    elif kind == 1:  # checkerboard
        p = int(rng.integers(2, 8))
        img = (((xx // p) + (yy // p)) % 2).astype(np.float32)
        img = 0.2 + 0.6 * img
    elif kind == 2:  # smooth blobs
        img = np.zeros((size, size), dtype=np.float32)
        for _ in range(int(rng.integers(2, 6))):
            cy, cx = rng.uniform(0, size, 2)
            r = rng.uniform(3, 10)
            amp = rng.uniform(0.3, 1.0)
            img += amp * np.exp(-(((yy - cy) ** 2 + (xx - cx) ** 2) / (2 * r * r)))
        img /= max(img.max(), 1e-6)
    else:  # glyph overlay on a gradient
        img = (xx + yy).astype(np.float32) / (2 * size)
        g = _glyph(int(rng.integers(0, 10)))
        g = np.kron(g, np.ones((3, 3), dtype=np.float32))
        y0 = int(rng.integers(0, size - g.shape[0]))
        x0 = int(rng.integers(0, size - g.shape[1]))
        img[y0 : y0 + g.shape[0], x0 : x0 + g.shape[1]] = np.maximum(
            img[y0 : y0 + g.shape[0], x0 : x0 + g.shape[1]], g * 0.9
        )
    return img.astype(np.float32)


def texture_dataset(n_train: int = 400, n_test: int = 16, seed: int = 77, size: int = 32):
    """Clean grayscale images in [0, 1]; shape (N, size, size, 1)."""
    rng = np.random.default_rng(seed)
    train = np.stack([_texture(rng, size) for _ in range(n_train)])[..., None]
    rng2 = np.random.default_rng(seed + 1)
    test = np.stack([_texture(rng2, size) for _ in range(n_test)])[..., None]
    return train, test


def add_awgn(images: np.ndarray, sigma255: float, seed: int = 5) -> np.ndarray:
    """Additive white Gaussian noise with σ given on the 0..255 scale."""
    rng = np.random.default_rng(seed)
    noisy = images + rng.normal(0.0, sigma255 / 255.0, images.shape)
    return np.clip(noisy, 0.0, 1.0).astype(np.float32)
