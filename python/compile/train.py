"""Float training loops (build-time only; never on the request path).

Minimal Adam over the layer-list models of ``models.qgraph``. Training
budgets are sized for CPU `make artifacts` runs (a few minutes total);
accuracies land in the high-80s/90s — enough to measure the *relative*
accuracy drop from approximate multipliers, which is what Table 5 reports.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .models.qgraph import Conv, Dense, float_forward

# ---------------------------------------------------------------------------
# parameter pytree <-> layer list
# ---------------------------------------------------------------------------


def get_params(layers):
    params = []
    for layer in layers:
        if isinstance(layer, (Conv, Dense)):
            params.append({"w": jnp.asarray(layer.w), "b": jnp.asarray(layer.b)})
    return params


def set_params(layers, params) -> None:
    i = 0
    for layer in layers:
        if isinstance(layer, (Conv, Dense)):
            layer.w = np.asarray(params[i]["w"])
            layer.b = np.asarray(params[i]["b"])
            i += 1


def _forward_with(layers, params, x):
    i = 0
    bound = []
    for layer in layers:
        if isinstance(layer, (Conv, Dense)):
            clone = type(layer)(
                w=params[i]["w"], b=params[i]["b"], relu=layer.relu, name=layer.name,
                **({"pad": layer.pad} if isinstance(layer, Conv) else {}),
            )
            bound.append(clone)
            i += 1
        else:
            bound.append(layer)
    return float_forward(bound, x)


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------


def _adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def _adam_step(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1**t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2**t), v)
    params = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh
    )
    return params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# training loops
# ---------------------------------------------------------------------------


def train_classifier(layers, x_train, y_train, *, steps=400, batch=64,
                     lr=1e-3, seed=3, log=print):
    """Cross-entropy training; mutates `layers` in place."""
    params = get_params(layers)
    state = _adam_init(params)
    rng = np.random.default_rng(seed)

    def loss_fn(params, xb, yb):
        logits = _forward_with(layers, params, xb)
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, yb[:, None], axis=1).mean()

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    t0 = time.time()
    for step in range(steps):
        idx = rng.integers(0, len(x_train), batch)
        xb = jnp.asarray(x_train[idx])
        yb = jnp.asarray(y_train[idx])
        loss, grads = grad_fn(params, xb, yb)
        params, state = _adam_step(params, grads, state, lr=lr)
        if step % 100 == 0 or step == steps - 1:
            log(f"  step {step:4d} loss {float(loss):.4f} "
                f"({time.time() - t0:.1f}s)")
    set_params(layers, params)
    return layers


def eval_classifier(layers, x_test, y_test, batch=100) -> float:
    """Float top-1 accuracy (%)."""
    correct = 0
    fwd = jax.jit(lambda x: float_forward(layers, x))
    for i in range(0, len(x_test), batch):
        logits = fwd(jnp.asarray(x_test[i : i + batch]))
        pred = np.asarray(jnp.argmax(logits, axis=1))
        correct += int((pred == y_test[i : i + batch]).sum())
    return 100.0 * correct / len(x_test)


def train_denoiser(layers, clean_train, *, steps=400, batch=16,
                   sigma_range=(10.0, 60.0), lr=1e-3, seed=5, log=print):
    """L2 denoising training on AWGN-corrupted textures."""
    from .models.zoo import ffdnet_input

    params = get_params(layers)
    state = _adam_init(params)
    rng = np.random.default_rng(seed)

    def loss_fn(params, xb, yb):
        out = _forward_with(layers, params, xb)
        return jnp.mean((out - yb) ** 2)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    t0 = time.time()
    for step in range(steps):
        idx = rng.integers(0, len(clean_train), batch)
        clean = clean_train[idx]
        sigma = float(rng.uniform(*sigma_range))
        noisy = np.clip(
            clean + rng.normal(0, sigma / 255.0, clean.shape), 0, 1
        ).astype(np.float32)
        xb = jnp.asarray(ffdnet_input(noisy, sigma))
        loss, grads = grad_fn(params, xb, jnp.asarray(clean))
        params, state = _adam_step(params, grads, state, lr=lr)
        if step % 100 == 0 or step == steps - 1:
            log(f"  step {step:4d} mse {float(loss):.5f} "
                f"({time.time() - t0:.1f}s)")
    set_params(layers, params)
    return layers
