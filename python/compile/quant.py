"""Post-training asymmetric uint8 quantization (gemmlowp-style).

real = scale · (q − zero_point), q ∈ [0, 255].

Weights are quantized per-tensor; activations get calibration-derived
ranges. The paper's multiplier is *unsigned* 8×8, which is exactly the
q·q product in this scheme — the approximate LUT replaces that product
while zero-point corrections remain exact adds (see approx_conv.py).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class QParams:
    """Quantization parameters of one tensor."""

    scale: float
    zero_point: int  # in [0, 255]

    def quantize(self, x: np.ndarray) -> np.ndarray:
        q = np.round(x / self.scale) + self.zero_point
        return np.clip(q, 0, 255).astype(np.uint8)

    def dequantize(self, q: np.ndarray) -> np.ndarray:
        return (q.astype(np.float32) - self.zero_point) * np.float32(self.scale)


def qparams_for_range(lo: float, hi: float) -> QParams:
    """Choose (scale, zero_point) covering [lo, hi] (always including 0)."""
    lo = min(float(lo), 0.0)
    hi = max(float(hi), 0.0)
    if hi - lo < 1e-12:
        return QParams(scale=1.0 / 255.0, zero_point=0)
    scale = (hi - lo) / 255.0
    zp = int(round(-lo / scale))
    return QParams(scale=scale, zero_point=int(np.clip(zp, 0, 255)))


def qparams_for_tensor(x: np.ndarray) -> QParams:
    return qparams_for_range(float(x.min()), float(x.max()))


def quantize_bias(b: np.ndarray, x_scale: float, w_scale: float) -> np.ndarray:
    """Bias in the int32 accumulator domain: b / (sx·sw)."""
    return np.round(b / (x_scale * w_scale)).astype(np.int32)


def requant_multiplier(x_scale: float, w_scale: float, y_scale: float) -> float:
    """Accumulator → next-layer-uint8 multiplier: sx·sw / sy."""
    return float(x_scale * w_scale / y_scale)
