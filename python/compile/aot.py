"""AOT artifact builder — the single Python entry point (`make artifacts`).

Produces everything the self-contained Rust binary needs:

    artifacts/
      manifest.json            inventory: models, params, luts, datasets
      mnist_cnn.hlo.txt        L2 quantized CNN, lowered to HLO *text*
      lenet5.hlo.txt
      ffdnet.hlo.txt
      kernel_matmul.hlo.txt    standalone L1 kernel (hot-path microbench)
      weights/<model>.bin      runtime weight parameters (uint8/int32)
      luts/<design>_<arch>.axlut   product LUTs, one per multiplier design
      data/digits_test.bin     500-image synthetic digit test set
      data/textures_test.bin   16 clean texture images (denoising eval)

HLO *text* (not serialized proto) is the interchange format: jax ≥ 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md). Large arrays must
be runtime parameters — the text printer elides big constants, which
would silently corrupt them.
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import sys
import time
from pathlib import Path

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as datagen
from . import train
from .approx import DESIGNS
from .approx.luts import fnv1a64, write_lut
from .approx.multiplier import ARCHITECTURES, product_lut
from .kernels.approx_conv import lut_matmul
from .models.qgraph import QModel
from .models.zoo import ffdnet_input, init_ffdnet_lite, init_lenet5, init_mnist_cnn

FAST = os.environ.get("AXMUL_FAST", "") == "1"

WEIGHTS_MAGIC = b"AXWTS01\x00"
DIGITS_MAGIC = b"AXDIG01\x00"
IMAGES_MAGIC = b"AXIMG01\x00"

_DTYPE_CODE = {"uint8": 0, "int32": 1, "float32": 2}


def log(msg: str) -> None:
    print(f"[aot] {msg}", flush=True)


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------


def to_hlo_text(fn, *specs) -> str:
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# binary writers
# ---------------------------------------------------------------------------


def write_weights(path: Path, params: list[tuple[str, np.ndarray]]) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    body = bytearray()
    body += struct.pack("<I", len(params))
    payload = bytearray()
    for name, arr in params:
        code = _DTYPE_CODE[str(arr.dtype)]
        nb = name.encode()
        body += struct.pack("<I", len(nb))
        body += nb
        body += struct.pack("<BB", code, arr.ndim)
        for d in arr.shape:
            body += struct.pack("<I", d)
        raw = np.ascontiguousarray(arr).astype(arr.dtype.newbyteorder("<")).tobytes()
        body += struct.pack("<I", len(raw))
        body += raw
        payload += raw
    with open(path, "wb") as f:
        f.write(WEIGHTS_MAGIC)
        f.write(bytes(body))
        f.write(struct.pack("<Q", fnv1a64(bytes(payload))))


def write_digits(path: Path, images: np.ndarray, labels: np.ndarray) -> None:
    """images (N, 28, 28, 1) float in [0,1] → u8; labels (N,) int."""
    path.parent.mkdir(parents=True, exist_ok=True)
    n, h, w, _ = images.shape
    with open(path, "wb") as f:
        f.write(DIGITS_MAGIC)
        f.write(struct.pack("<III", n, h, w))
        f.write((images[..., 0] * 255).round().astype(np.uint8).tobytes())
        f.write(labels.astype(np.uint8).tobytes())


def write_images(path: Path, images: np.ndarray) -> None:
    """images (N, H, W, 1) float in [0,1] → u8."""
    path.parent.mkdir(parents=True, exist_ok=True)
    n, h, w, _ = images.shape
    with open(path, "wb") as f:
        f.write(IMAGES_MAGIC)
        f.write(struct.pack("<III", n, h, w))
        f.write((images[..., 0] * 255).round().astype(np.uint8).tobytes())


# ---------------------------------------------------------------------------
# build steps
# ---------------------------------------------------------------------------


def build_luts(out: Path) -> dict:
    """Every design in the proposed architecture + the proposed design in
    all three architectures + the exact reference."""
    entries = {}

    def emit(key: str, lut_u32: np.ndarray):
        rel = f"luts/{key.replace(':', '_')}.axlut"
        write_lut(out / rel, key, lut_u32)
        entries[key] = rel

    exact = (np.arange(65536, dtype=np.uint32) >> 8) * (
        np.arange(65536, dtype=np.uint32) & 255
    )
    emit("exact:reference", exact.astype(np.uint32))
    for name, design in DESIGNS.items():
        emit(f"{name}:proposed", product_lut(design, "proposed"))
    for arch in ARCHITECTURES:
        if arch != "proposed":
            emit(f"proposed:{arch}", product_lut(DESIGNS["proposed"], arch))
    log(f"{len(entries)} LUTs written")
    return entries


def model_entry(qm: QModel, hlo_rel: str, weights_rel: str, in_shape, out_shape):
    params = [
        {"name": n, "dtype": str(a.dtype), "shape": list(a.shape)}
        for n, a in qm.weight_arrays()
    ]
    params.append({"name": "lut", "dtype": "int32", "shape": [65536]})
    return {
        "hlo": hlo_rel,
        "weights": weights_rel,
        "input": {"shape": list(in_shape), "dtype": "f32"},
        "output": {"shape": list(out_shape), "dtype": "f32"},
        "params": params,
    }


def lower_model(qm: QModel, out: Path, name: str, in_shape) -> dict:
    specs = [jax.ShapeDtypeStruct(in_shape, np.float32)]
    weight_arrays = qm.weight_arrays()
    for _, arr in weight_arrays:
        specs.append(jax.ShapeDtypeStruct(arr.shape, arr.dtype))
    specs.append(jax.ShapeDtypeStruct((65536,), np.int32))

    def fn(x, *params):
        return (qm.apply(x, *params),)

    t0 = time.time()
    text = to_hlo_text(fn, *specs)
    (out / f"{name}.hlo.txt").write_text(text)
    # output shape from an abstract eval
    out_shape = jax.eval_shape(fn, *specs)[0].shape
    log(f"{name}: lowered to HLO ({len(text) / 1e3:.0f} kB, "
        f"{time.time() - t0:.1f}s), output {tuple(out_shape)}")
    write_weights(out / "weights" / f"{name}.bin", weight_arrays)
    return model_entry(qm, f"{name}.hlo.txt", f"weights/{name}.bin",
                       in_shape, out_shape)


def build_mnist_models(out: Path, manifest: dict) -> None:
    n_train, n_test = (600, 100) if FAST else (5000, 500)
    steps_cnn = 60 if FAST else 500
    steps_lenet = 60 if FAST else 600
    log(f"digit corpus: {n_train} train / {n_test} test")
    x_train, y_train, x_test, y_test = datagen.digits_dataset(n_train, n_test)
    write_digits(out / "data/digits_test.bin", x_test, y_test)
    manifest["data"]["digits_test"] = {
        "file": "data/digits_test.bin",
        "count": int(len(x_test)),
    }

    batch = 32
    for name, init, steps in (
        ("mnist_cnn", init_mnist_cnn, steps_cnn),
        ("lenet5", init_lenet5, steps_lenet),
    ):
        log(f"training {name} ({steps} steps)")
        layers = init()
        train.train_classifier(layers, x_train, y_train, steps=steps, log=log)
        acc = train.eval_classifier(layers, x_test, y_test)
        log(f"{name}: float accuracy {acc:.2f}%")
        qm = QModel.build(name, layers, x_train[:256])
        entry = lower_model(qm, out, name, (batch, 28, 28, 1))
        entry["float_accuracy"] = acc
        entry["batch"] = batch
        manifest["models"][name] = entry


def build_ffdnet(out: Path, manifest: dict) -> None:
    steps = 60 if FAST else 500
    n_train = 80 if FAST else 400
    clean_train, clean_test = datagen.texture_dataset(n_train=n_train)
    write_images(out / "data/textures_test.bin", clean_test)
    manifest["data"]["textures_test"] = {
        "file": "data/textures_test.bin",
        "count": int(len(clean_test)),
    }
    log(f"training ffdnet_lite ({steps} steps)")
    layers = init_ffdnet_lite()
    train.train_denoiser(layers, clean_train, steps=steps, log=log)
    # calibrate over a mix of noise levels
    rng = np.random.default_rng(9)
    calib_clean = clean_train[:32]
    noisy = np.clip(
        calib_clean + rng.normal(0, 35 / 255.0, calib_clean.shape), 0, 1
    ).astype(np.float32)
    calib = ffdnet_input(noisy, 35.0)
    qm = QModel.build("ffdnet", layers, calib, in_range=(0.0, 1.0))
    batch = 4
    entry = lower_model(qm, out, "ffdnet", (batch, 32, 32, 2))
    entry["batch"] = batch
    manifest["models"]["ffdnet"] = entry


def build_kernel_artifact(out: Path, manifest: dict) -> None:
    """Standalone L1 kernel for the Rust hot-path microbenchmark."""
    m, k, n = 256, 64, 32

    def fn(x, w, lut):
        return (lut_matmul(x, w, lut),)

    text = to_hlo_text(
        fn,
        jax.ShapeDtypeStruct((m, k), np.uint8),
        jax.ShapeDtypeStruct((k, n), np.uint8),
        jax.ShapeDtypeStruct((65536,), np.int32),
    )
    (out / "kernel_matmul.hlo.txt").write_text(text)
    manifest["models"]["kernel_matmul"] = {
        "hlo": "kernel_matmul.hlo.txt",
        "weights": None,
        "input": {"shape": [m, k], "dtype": "u8"},
        "output": {"shape": [m, n], "dtype": "i32"},
        "params": [
            {"name": "x", "dtype": "uint8", "shape": [m, k]},
            {"name": "w", "dtype": "uint8", "shape": [k, n]},
            {"name": "lut", "dtype": "int32", "shape": [65536]},
        ],
    }
    log(f"kernel_matmul: lowered ({len(text) / 1e3:.0f} kB)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    t0 = time.time()
    manifest: dict = {"version": 1, "fast": FAST, "models": {}, "data": {}}
    manifest["luts"] = build_luts(out)
    build_kernel_artifact(out, manifest)
    build_mnist_models(out, manifest)
    build_ffdnet(out, manifest)
    (out / "manifest.json").write_text(json.dumps(manifest, indent=2))
    log(f"done in {time.time() - t0:.1f}s → {out / 'manifest.json'}")


if __name__ == "__main__":
    main()
