"""Pure-jnp oracles for the L1 kernels — the correctness ground truth.

Every kernel in ``approx_conv.py`` has a reference here computed with
plain jnp ops (no pallas); pytest (`test_kernel.py`) sweeps shapes and
dtypes with hypothesis and asserts exact equality (integer arithmetic —
no tolerance needed).
"""

from __future__ import annotations

import jax.numpy as jnp


def lut_matmul_ref(x_q, w_q, lut):
    """Reference for ``lut_matmul``: explicit gather, no tiling."""
    x = x_q.astype(jnp.int32)
    w = w_q.astype(jnp.int32)
    idx = x[:, :, None] * 256 + w[None, :, :]  # (M, K, N)
    prods = jnp.take(lut, idx.reshape(-1), axis=0).reshape(idx.shape)
    return prods.sum(axis=1).astype(jnp.int32)


def quantized_acc_ref(x_q, w_q, lut, x_zp, w_zp):
    """Reference for ``quantized_acc_to_int``."""
    k = x_q.shape[1]
    acc = lut_matmul_ref(x_q, w_q, lut)
    x_sum = jnp.sum(x_q.astype(jnp.int32), axis=1, keepdims=True)
    w_sum = jnp.sum(w_q.astype(jnp.int32), axis=0, keepdims=True)
    return acc - w_zp * x_sum - x_zp * w_sum + k * x_zp * w_zp


def exact_quant_matmul_ref(x_q, w_q, x_zp, w_zp):
    """Exact-arithmetic version (what a float multiplier would compute in
    the quantized domain): used to quantify approximation-induced error."""
    x = x_q.astype(jnp.int32) - x_zp
    w = w_q.astype(jnp.int32) - w_zp
    return x @ w


def conv2d_ref(x_q, w_q, lut, x_zp, w_zp):
    """Reference valid conv via explicit loops over kernel taps."""
    b, h, w_dim, cin = x_q.shape
    kh, kw, _, cout = w_q.shape
    oh, ow = h - kh + 1, w_dim - kw + 1
    acc = jnp.zeros((b, oh, ow, cout), jnp.int32)
    for i in range(kh):
        for j in range(kw):
            patch = x_q[:, i : i + oh, j : j + ow, :].reshape(b * oh * ow, cin)
            wmat = w_q[i, j].reshape(cin, cout)
            acc = acc + quantized_acc_ref(patch, wmat, lut, x_zp, w_zp).reshape(
                b, oh, ow, cout
            )
    return acc
