"""L1 Pallas kernels: LUT-gather approximate arithmetic.

The entire approximate multiplier (any compressor design × PPR
architecture) is a 256×256→u32 table, passed at *runtime* as an i32[65536]
parameter — one compiled executable therefore serves every multiplier
design, and the Rust coordinator swaps designs by swapping LUT buffers.

`lut_matmul` is the hot spot: a quantized (uint8 × uint8 → int32) matmul
where every scalar product is `lut[a*256 + b]`. The kernel tiles the M
dimension (`BlockSpec` grid) so that on a real TPU each block keeps the
256 KiB LUT resident in VMEM and streams operand tiles; the K loop is a
`fori_loop` so the index/gather working set stays at M_tile×N. On CPU we
lower with `interpret=True` (Mosaic is TPU-only); see DESIGN.md
§Hardware-adaptation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# M-dimension tile. 128 rows × N≤128 cols of i32 accumulator plus the
# 256 KiB LUT keeps VMEM usage ≈ 0.4 MiB per block — well inside a
# TPU core's ~16 MiB VMEM with generous double-buffering headroom.
BLOCK_M = 128


def _lut_matmul_kernel(x_ref, w_ref, lut_ref, o_ref):
    """One M-tile: acc[m, n] = Σ_k lut[x[m, k] · 256 + w[k, n]]."""
    x = x_ref[...].astype(jnp.int32)  # (bm, K) uint8 values
    w = w_ref[...].astype(jnp.int32)  # (K, N)
    bm, k_dim = x.shape
    n_dim = w.shape[1]
    lut = lut_ref[...]

    def body(k, acc):
        idx = x[:, k][:, None] * 256 + w[k, :][None, :]  # (bm, N)
        return acc + jnp.take(lut, idx.reshape(-1), axis=0).reshape(bm, n_dim)

    acc = jax.lax.fori_loop(
        0, k_dim, body, jnp.zeros((bm, n_dim), jnp.int32)
    )
    o_ref[...] = acc


def lut_matmul(x_q: jax.Array, w_q: jax.Array, lut: jax.Array) -> jax.Array:
    """Approximate uint8 matmul via product-LUT gathers.

    Args:
      x_q: (M, K) uint8 quantized activations.
      w_q: (K, N) uint8 quantized weights.
      lut: (65536,) int32 product table, index = a*256 + b.

    Returns:
      (M, N) int32 accumulator (Σ of LUT products).
    """
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2, (x_q.shape, w_q.shape)
    assert lut.shape == (65536,)

    # pad M to a multiple of the block
    m_pad = (-m) % BLOCK_M
    if m_pad:
        x_q = jnp.pad(x_q, ((0, m_pad), (0, 0)))
    grid = (x_q.shape[0] // BLOCK_M,)

    out = pl.pallas_call(
        _lut_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_M, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((65536,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_M, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x_q.shape[0], n), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x_q, w_q, lut)
    return out[:m]


def quantized_acc_to_int(x_q, w_q, lut, x_zp: int, w_zp: int):
    """Full asymmetric-quantization accumulator.

    real_x = sx·(q_x − zx), real_w = sw·(q_w − zw) ⇒
    Σ real_x·real_w = sx·sw·(Σ q_x·q_w − zw·Σ q_x − zx·Σ q_w + K·zx·zw)

    Only the Σ q_x·q_w term uses the (approximate) multiplier; the
    correction sums are exact adders in hardware.
    """
    m, k = x_q.shape
    acc = lut_matmul(x_q, w_q, lut)
    x_sum = jnp.sum(x_q.astype(jnp.int32), axis=1, keepdims=True)  # (M,1)
    w_sum = jnp.sum(w_q.astype(jnp.int32), axis=0, keepdims=True)  # (1,N)
    return acc - w_zp * x_sum - x_zp * w_sum + k * x_zp * w_zp


@functools.partial(jax.jit, static_argnames=("kh", "kw"))
def im2col(x, kh: int, kw: int):
    """Extract valid-convolution patches.

    Args:
      x: (B, H, W, C).
    Returns:
      (B, OH, OW, kh*kw*C) patch tensor.
    """
    b, h, w, c = x.shape
    oh, ow = h - kh + 1, w - kw + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(jax.lax.slice(x, (0, i, j, 0), (b, i + oh, j + ow, c)))
    return jnp.concatenate(cols, axis=-1)


def approx_conv2d(x_q, w_q, lut, x_zp: int, w_zp: int):
    """Valid 2-D convolution with the approximate multiplier.

    Args:
      x_q: (B, H, W, Cin) uint8.
      w_q: (KH, KW, Cin, Cout) uint8.
    Returns:
      (B, OH, OW, Cout) int32 accumulator (quantization-corrected).
    """
    kh, kw, cin, cout = w_q.shape
    patches = im2col(x_q, kh, kw)  # (B, OH, OW, kh*kw*Cin)
    b, oh, ow, k = patches.shape
    flat = patches.reshape(b * oh * ow, k)
    wmat = w_q.reshape(kh * kw * cin, cout)
    acc = quantized_acc_to_int(flat, wmat, lut, x_zp, w_zp)
    return acc.reshape(b, oh, ow, cout)
