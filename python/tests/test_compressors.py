"""Compressor behavioral tests: paper Table 1 + §2 survey claims."""

import itertools

import pytest

from compile.approx.compressors import (
    DESIGNS,
    EXACT,
    HIGH_ACCURACY,
    COMBO_PROB_NUM,
    proposed_from_equations,
)


def test_table1_truth_table():
    """Paper Table 1: proposed == exact except 1111 → 3."""
    t = DESIGNS["proposed"]
    for idx in range(16):
        exact = bin(idx).count("1")
        expect = 3 if idx == 15 else exact
        assert t.values[idx] == expect, f"combo {idx:04b}"


def test_equations_match_table1():
    """Eqs. (1)-(3) (with the Eq. 2 typo fixed) reproduce Table 1."""
    t = DESIGNS["proposed"]
    for x4, x3, x2, x1 in itertools.product([0, 1], repeat=4):
        idx = x1 + 2 * x2 + 4 * x3 + 8 * x4
        assert proposed_from_equations(x1, x2, x3, x4) == t.values[idx]


def test_probability_numerators_sum_to_256():
    assert sum(COMBO_PROB_NUM) == 256
    assert COMBO_PROB_NUM[0] == 81
    assert COMBO_PROB_NUM[15] == 1


@pytest.mark.parametrize(
    "name,prob",
    [
        ("exact", 0),
        ("proposed", 1),
        ("yang18", 1),
        ("kong19_d1", 1),
        ("kong19_d5", 1),
        ("kumari16_d1", 1),
        ("strollo17_d3", 1),
        ("krishna12", 19),
        ("caam15", 16),
        ("kumari16_d2", 55),
        ("strollo17_d2", 4),
        ("zhang13", 70),
    ],
)
def test_error_probabilities_match_paper_table3(name, prob):
    assert DESIGNS[name].error_probability_num() == prob


def test_kumari16_d2_closed_form():
    """The OR/AND-only structure independently yields 7 error combos."""
    t = DESIGNS["kumari16_d2"]
    assert len(t.error_combos()) == 7


def test_high_accuracy_class_errs_only_on_all_ones():
    for name in ("proposed", "yang18", "kong19_d1", "kong19_d5",
                 "kumari16_d1", "strollo17_d3"):
        assert DESIGNS[name].error_combos() == [15], name


def test_exact_table_has_no_errors():
    assert EXACT.error_combos() == []
    assert EXACT.values[15] == 4


def test_carry_sum_encoding_roundtrip():
    ct, st = HIGH_ACCURACY.carry_sum_tables()
    for idx in range(16):
        assert 2 * int(ct[idx]) + int(st[idx]) == HIGH_ACCURACY.values[idx]
