"""Multiplier architecture tests: paper Table 2 fingerprints + invariants."""

import numpy as np
import pytest

from compile.approx.compressors import DESIGNS, EXACT
from compile.approx.multiplier import (
    error_metrics,
    multiply_exhaustive,
    multiply_pairs,
    product_lut,
    truncation_compensation,
)


@pytest.fixture(scope="module")
def proposed_lut():
    return multiply_exhaustive(DESIGNS["proposed"], "proposed")


def test_exact_compressor_is_exact_in_proposed_arch():
    lut = multiply_exhaustive(EXACT, "proposed")
    pairs = np.arange(65536, dtype=np.int64)
    assert np.array_equal(lut, (pairs >> 8) * (pairs & 255))


def test_exact_compressor_is_exact_in_design1():
    lut = multiply_exhaustive(EXACT, "design1")
    pairs = np.arange(65536, dtype=np.int64)
    assert np.array_equal(lut, (pairs >> 8) * (pairs & 255))


def test_calibrated_fingerprint_high_accuracy(proposed_lut):
    """The frozen tree: ER 6.453 / NMED 0.058 / MRED 0.121 (DESIGN.md §4)."""
    er, nmed, mred = error_metrics(proposed_lut)
    assert abs(er - 6.453) < 0.01
    assert abs(nmed - 0.058) < 0.005
    assert abs(mred - 0.121) < 0.005


def test_kumari16_d2_fingerprint():
    er, nmed, mred = error_metrics(multiply_exhaustive(DESIGNS["kumari16_d2"], "proposed"))
    # paper Table 2: 86.326 / 1.879 / 9.551 — ER and NMED land on target,
    # MRED within the documented deviation band
    assert abs(er - 86.636) < 0.05
    assert abs(nmed - 1.860) < 0.01
    assert 7.0 < mred < 10.5


def test_error_ordering_matches_table2(proposed_lut):
    """Cross-design MRED ordering of Table 2 must hold."""
    mred = {
        name: error_metrics(multiply_exhaustive(DESIGNS[name], "proposed"))[2]
        for name in ("proposed", "strollo17_d2", "krishna12", "kumari16_d2", "zhang13")
    }
    assert mred["proposed"] < mred["strollo17_d2"] < mred["krishna12"]
    assert mred["krishna12"] < mred["kumari16_d2"] < mred["zhang13"]


def test_design1_more_accurate_than_proposed_arch():
    """Exact MSB compressors (Fig. 2a) must reduce error vs Fig. 2c."""
    t = DESIGNS["proposed"]
    d1 = error_metrics(multiply_exhaustive(t, "design1"))
    pr = error_metrics(multiply_exhaustive(t, "proposed"))
    assert d1[2] < pr[2]


def test_design2_truncation_bounded():
    """With exact compressors, Design-2's error is pure truncation."""
    lut = multiply_exhaustive(EXACT, "design2")
    pairs = np.arange(65536, dtype=np.int64)
    exact = (pairs >> 8) * (pairs & 255)
    ed = np.abs(lut - exact)
    assert ed.max() <= 49  # max truncated mass (1+2+3·4+4·8=49) vs comp 12


def test_compensation_constant():
    assert truncation_compensation() == 12


def test_small_operand_exactness(proposed_lut):
    """Operands ≤ 7 never hit the all-ones combo in any column."""
    for a in range(8):
        for b in range(8):
            assert proposed_lut[a * 256 + b] == a * b


def test_fifteen_squared_fingerprint(proposed_lut):
    """15·15 loses exactly 2³ (column 3 all-ones) — Rust asserts the same."""
    assert proposed_lut[15 * 256 + 15] == 217


def test_product_lut_dtype_and_range():
    lut = product_lut(DESIGNS["zhang13"], "proposed")
    assert lut.dtype == np.uint32
    assert lut.max() < (1 << 17)


def test_multiply_pairs_vector_api():
    a = np.array([3, 200, 255], dtype=np.uint16)
    b = np.array([5, 100, 255], dtype=np.uint16)
    out = multiply_pairs(a, b, EXACT, "proposed")
    assert list(out) == [15, 20000, 65025]
