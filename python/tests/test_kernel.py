"""L1 Pallas kernel vs pure-jnp oracle — the core correctness signal.

Integer arithmetic end to end, so equality is exact (no tolerances).
Hypothesis sweeps shapes, operand distributions and LUT choices.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.approx.compressors import DESIGNS
from compile.approx.multiplier import product_lut
from compile.kernels.approx_conv import (
    approx_conv2d,
    im2col,
    lut_matmul,
    quantized_acc_to_int,
)
from compile.kernels.ref import (
    conv2d_ref,
    exact_quant_matmul_ref,
    lut_matmul_ref,
    quantized_acc_ref,
)


def exact_lut():
    i = np.arange(65536, dtype=np.int32)
    return jnp.asarray((i >> 8) * (i & 255), dtype=jnp.int32)


@pytest.fixture(scope="module")
def proposed_lut_i32():
    return jnp.asarray(product_lut(DESIGNS["proposed"], "proposed").astype(np.int32))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 300),
    k=st.integers(1, 40),
    n=st.integers(1, 24),
    seed=st.integers(0, 2**31),
)
def test_lut_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 256, (m, k), dtype=np.uint8)
    w = rng.integers(0, 256, (k, n), dtype=np.uint8)
    lut = exact_lut()
    got = np.asarray(lut_matmul(jnp.asarray(x), jnp.asarray(w), lut))
    want = np.asarray(lut_matmul_ref(jnp.asarray(x), jnp.asarray(w), lut))
    assert np.array_equal(got, want)
    # exact LUT ⇒ plain integer matmul
    assert np.array_equal(got, x.astype(np.int64) @ w.astype(np.int64))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_lut_matmul_with_approx_lut(proposed_lut_i32, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 256, (64, 16), dtype=np.uint8)
    w = rng.integers(0, 256, (16, 8), dtype=np.uint8)
    got = np.asarray(lut_matmul(jnp.asarray(x), jnp.asarray(w), proposed_lut_i32))
    want = np.asarray(lut_matmul_ref(jnp.asarray(x), jnp.asarray(w), proposed_lut_i32))
    assert np.array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 32),
    n=st.integers(1, 12),
    zx=st.integers(0, 255),
    zw=st.integers(0, 255),
    seed=st.integers(0, 2**31),
)
def test_quantized_acc_exact_lut_equals_integer_matmul(m, k, n, zx, zw, seed):
    """With the exact LUT, the zero-point-corrected accumulator must equal
    the plain (q−z)·(q−z) integer matmul."""
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 256, (m, k), dtype=np.uint8)
    w = rng.integers(0, 256, (k, n), dtype=np.uint8)
    got = np.asarray(
        quantized_acc_to_int(jnp.asarray(x), jnp.asarray(w), exact_lut(), zx, zw)
    )
    want = np.asarray(exact_quant_matmul_ref(jnp.asarray(x), jnp.asarray(w), zx, zw))
    assert np.array_equal(got, want)
    ref = np.asarray(quantized_acc_ref(jnp.asarray(x), jnp.asarray(w), exact_lut(), zx, zw))
    assert np.array_equal(got, ref)


@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(5, 12),
    kh=st.integers(1, 3),
    cin=st.integers(1, 3),
    cout=st.integers(1, 4),
    seed=st.integers(0, 2**31),
)
def test_conv2d_matches_ref(proposed_lut_i32, b, h, kh, cin, cout, seed):
    rng = np.random.default_rng(seed)
    w_dim = h + 1
    x = rng.integers(0, 256, (b, h, w_dim, cin), dtype=np.uint8)
    w = rng.integers(0, 256, (kh, kh, cin, cout), dtype=np.uint8)
    got = np.asarray(
        approx_conv2d(jnp.asarray(x), jnp.asarray(w), proposed_lut_i32, 3, 7)
    )
    want = np.asarray(
        conv2d_ref(jnp.asarray(x), jnp.asarray(w), proposed_lut_i32, 3, 7)
    )
    assert np.array_equal(got, want)


def test_im2col_shapes_and_content():
    x = jnp.arange(2 * 5 * 6 * 3, dtype=jnp.uint8).reshape(2, 5, 6, 3)
    p = im2col(x, 3, 3)
    assert p.shape == (2, 3, 4, 27)
    # first patch equals the flattened 3×3 window, tap-major
    manual = jnp.concatenate(
        [x[0, i, j, :] for i in range(3) for j in range(3)]
    )
    assert np.array_equal(np.asarray(p[0, 0, 0]), np.asarray(manual))


def test_block_boundary_sizes():
    """M not divisible by the pallas block must be padded correctly."""
    lut = exact_lut()
    for m in (1, 127, 128, 129, 255):
        x = np.full((m, 4), 7, dtype=np.uint8)
        w = np.full((4, 2), 9, dtype=np.uint8)
        out = np.asarray(lut_matmul(jnp.asarray(x), jnp.asarray(w), lut))
        assert out.shape == (m, 2)
        assert (out == 4 * 63).all()
