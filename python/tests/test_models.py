"""L2 model tests: shapes, quantization fidelity, end-to-end sanity."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile.approx.compressors import DESIGNS
from compile.approx.multiplier import product_lut
from compile.data import add_awgn, digits_dataset, texture_dataset
from compile.models.qgraph import Conv, Dense, Flatten, MaxPool2, QModel, float_forward
from compile.models.zoo import (
    ffdnet_input,
    init_ffdnet_lite,
    init_lenet5,
    init_mnist_cnn,
)


def exact_lut_i32():
    i = np.arange(65536, dtype=np.int64)
    return jnp.asarray(((i >> 8) * (i & 255)).astype(np.int32))


def test_float_shapes():
    x = jnp.zeros((2, 28, 28, 1), jnp.float32)
    assert float_forward(init_mnist_cnn(), x).shape == (2, 10)
    assert float_forward(init_lenet5(), x).shape == (2, 10)
    xf = jnp.zeros((2, 32, 32, 2), jnp.float32)
    assert float_forward(init_ffdnet_lite(), xf).shape == (2, 32, 32, 1)


def test_digits_dataset_properties():
    x_tr, y_tr, x_te, y_te = digits_dataset(200, 50, seed=4)
    assert x_tr.shape == (200, 28, 28, 1) and x_te.shape == (50, 28, 28, 1)
    assert set(np.unique(y_tr)) <= set(range(10))
    assert x_tr.min() >= 0.0 and x_tr.max() <= 1.0
    # determinism
    x2, y2, _, _ = digits_dataset(200, 50, seed=4)
    assert np.array_equal(x_tr, x2) and np.array_equal(y_tr, y2)


def test_texture_dataset_and_noise():
    tr, te = texture_dataset(20, 4)
    assert tr.shape == (20, 32, 32, 1)
    noisy = add_awgn(te, 50.0)
    assert noisy.shape == te.shape
    assert float(np.abs(noisy - te).mean()) > 0.05


def test_ffdnet_input_packing():
    te = np.zeros((3, 32, 32, 1), np.float32)
    packed = ffdnet_input(te, 25.0)
    assert packed.shape == (3, 32, 32, 2)
    assert np.allclose(packed[..., 1], 25.0 / 255.0)


@pytest.fixture(scope="module")
def tiny_qmodel():
    """A small trained-ish model quantized with calibration data."""
    rng = np.random.default_rng(0)
    layers = [
        Conv(rng.normal(0, 0.3, (3, 3, 1, 4)).astype(np.float32),
             rng.normal(0, 0.1, (4,)).astype(np.float32), relu=True, name="conv"),
        MaxPool2(),
        Flatten(),
        Dense(rng.normal(0, 0.2, (13 * 13 * 4, 6)).astype(np.float32),
              np.zeros(6, np.float32), relu=False, name="fc"),
    ]
    calib = rng.uniform(0, 1, (16, 28, 28, 1)).astype(np.float32)
    return QModel.build("tiny", layers, calib), calib


def test_quantized_model_tracks_float(tiny_qmodel):
    """With the exact LUT, quantized outputs ≈ float outputs."""
    qm, calib = tiny_qmodel
    x = calib[:4]
    params = [jnp.asarray(a) for _, a in qm.weight_arrays()]
    q_out = np.asarray(qm.apply(jnp.asarray(x), *params, exact_lut_i32()))
    f_out = np.asarray(qm.float_apply(jnp.asarray(x)))
    assert q_out.shape == f_out.shape
    # quantization noise only — outputs correlate strongly
    denom = np.abs(f_out).max() + 1e-6
    assert np.abs(q_out - f_out).max() / denom < 0.15
    # and the top-1 decision matches for most rows
    agree = (q_out.argmax(1) == f_out.argmax(1)).mean()
    assert agree >= 0.75


def test_approx_lut_changes_output_slightly(tiny_qmodel):
    qm, calib = tiny_qmodel
    x = calib[:4]
    params = [jnp.asarray(a) for _, a in qm.weight_arrays()]
    exact_out = np.asarray(qm.apply(jnp.asarray(x), *params, exact_lut_i32()))
    lut = jnp.asarray(product_lut(DESIGNS["proposed"], "proposed").astype(np.int32))
    approx_out = np.asarray(qm.apply(jnp.asarray(x), *params, lut))
    # different but close
    assert not np.array_equal(exact_out, approx_out)
    denom = np.abs(exact_out).max() + 1e-6
    assert np.abs(exact_out - approx_out).max() / denom < 0.2


def test_weight_arrays_order_is_stable(tiny_qmodel):
    qm, _ = tiny_qmodel
    names = [n for n, _ in qm.weight_arrays()]
    assert names == ["conv0_w", "conv0_b", "fc3_w", "fc3_b"]
    dtypes = [a.dtype for _, a in qm.weight_arrays()]
    assert [str(d) for d in dtypes] == ["uint8", "int32", "uint8", "int32"]
