"""LUT binary format round-trip and checksum behaviour."""

import numpy as np
import pytest

from compile.approx.compressors import DESIGNS
from compile.approx.luts import ENTRIES, fnv1a64, read_lut, write_lut
from compile.approx.multiplier import product_lut


def test_roundtrip(tmp_path):
    lut = product_lut(DESIGNS["proposed"], "proposed")
    p = tmp_path / "x.axlut"
    write_lut(p, "proposed:proposed", lut)
    name, back = read_lut(p)
    assert name == "proposed:proposed"
    assert np.array_equal(back, lut)


def test_corruption_detected(tmp_path):
    lut = np.zeros(ENTRIES, dtype=np.uint32)
    p = tmp_path / "x.axlut"
    write_lut(p, "z", lut)
    raw = bytearray(p.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    p.write_bytes(bytes(raw))
    with pytest.raises(ValueError, match="checksum"):
        read_lut(p)


def test_fnv_vectors():
    assert fnv1a64(b"") == 0xCBF29CE484222325
    assert fnv1a64(b"a") == 0xAF63DC4C8601EC8C


def test_bad_magic(tmp_path):
    p = tmp_path / "bad.axlut"
    p.write_bytes(b"NOTALUT!" + b"\x00" * 64)
    with pytest.raises(ValueError, match="magic"):
        read_lut(p)
